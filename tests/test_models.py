"""Per-architecture smoke tests (reduced configs) and cache-consistency
properties: prefill/verify/decode paths must reproduce full-context
logits, and speculative rollback (partial accept) must be exact."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import Model

KEY = jax.random.key(1)
ARCHS = list(registry.ASSIGNED)


def _setup(name, cap_exact=True):
    cfg = registry.smoke_config(name)
    if cfg.n_experts and cap_exact:
        # lift MoE capacity so the dispatch path has zero drops and the
        # train path is exactly comparable with the exact verify path.
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    m = Model(cfg)
    return cfg, m, m.init(KEY)


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_shapes(name):
    cfg, m, params = _setup(name, cap_exact=False)
    toks = jax.random.randint(jax.random.key(2), (2, 32), 0, cfg.vocab)
    logits, _, aux = m.apply(params, toks, extras=m.make_extras(2), mode="train")
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab])))
    assert bool(jnp.isfinite(aux))
    # padded vocab columns are masked out
    if cfg.padded_vocab > cfg.vocab:
        assert float(jnp.max(logits[..., cfg.vocab :])) < -1e20


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    """One gradient step on the reduced config: finite loss and grads."""
    from repro.training import train as training

    cfg, m, params = _setup(name, cap_exact=False)
    toks = jax.random.randint(jax.random.key(4), (2, 33), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    loss, grads = jax.value_and_grad(
        lambda p: training.loss_fn(m, p, batch, m.make_extras(2))[0]
    )(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)


@pytest.mark.parametrize("name", ARCHS)
def test_incremental_matches_full(name):
    cfg, m, params = _setup(name)
    b, s, pre, ch = 2, 40, 24, 8
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab)
    extras = m.make_extras(b)
    full, _, _ = m.apply(params, toks, extras=extras, mode="train")

    cache = m.init_cache(b, max_len=64, chunk_slack=ch)
    lg, cache, _ = m.apply(
        params, toks[:, :pre], cache=cache, extras=extras, mode="prefill"
    )
    assert float(jnp.max(jnp.abs(lg - full[:, :pre]))) < 2e-3
    lens = jnp.full((b,), pre, jnp.int32)
    pos = pre
    while pos < s:
        chunk = toks[:, pos : pos + ch]
        lg, vcache, _ = m.apply(
            params, chunk, cache=cache, lens=lens, extras=extras, mode="verify"
        )
        err = float(jnp.max(jnp.abs(lg - full[:, pos : pos + chunk.shape[1]])))
        assert err < 2e-3, (pos, err)
        cache = m.commit_cache(
            vcache, jnp.full((b,), chunk.shape[1] - 1, jnp.int32)
        )
        lens = lens + chunk.shape[1]
        pos += ch


@pytest.mark.parametrize("name", ARCHS)
def test_speculative_rollback(name):
    """Committing tau < chunk-1 then continuing == fresh run on the
    accepted prefix (KV ring staleness + SSM state checkpoint)."""
    cfg, m, params = _setup(name)
    b, pre, ch, tau = 2, 24, 6, 2
    toks = jax.random.randint(jax.random.key(3), (b, pre + ch), 0, cfg.vocab)
    extras = m.make_extras(b)

    cache = m.init_cache(b, max_len=64, chunk_slack=8)
    _, cache, _ = m.apply(
        params, toks[:, :pre], cache=cache, extras=extras, mode="prefill"
    )
    lens = jnp.full((b,), pre, jnp.int32)
    _, vcache, _ = m.apply(
        params, toks[:, pre : pre + ch], cache=cache, lens=lens,
        extras=extras, mode="verify",
    )
    cache = m.commit_cache(vcache, jnp.full((b,), tau, jnp.int32))
    lens = lens + tau + 1
    chunk2 = jax.random.randint(jax.random.key(9), (b, ch), 0, cfg.vocab)
    lg_a, _, _ = m.apply(
        params, chunk2, cache=cache, lens=lens, extras=extras, mode="verify"
    )

    seq = jnp.concatenate([toks[:, : pre + tau + 1], chunk2], axis=1)
    full, _, _ = m.apply(params, seq, extras=extras, mode="train")
    err = float(jnp.max(jnp.abs(lg_a - full[:, pre + tau + 1 :])))
    assert err < 2e-3, err


def test_drafter_configs_valid():
    from repro.models.common import drafter_of

    for name in ARCHS:
        cfg = registry.get_config(name)
        d = drafter_of(cfg)
        assert d.n_layers < cfg.n_layers
        if d.n_heads:
            assert d.n_heads % d.n_kv == 0
        assert d.vocab == cfg.vocab


def test_full_config_values_match_assignment():
    """The exact assigned numbers (spot-check each arch)."""
    c = registry.get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        56, 6144, 48, 8, 16384, 32768) and (c.n_experts, c.top_k) == (8, 2)
    c = registry.get_config("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab,
            c.ssm_state) == (38, 2048, 32, 32, 8192, 32000, 64)
    c = registry.get_config("olmo-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        16, 2048, 16, 16, 8192, 50304) and c.norm == "np_layernorm"
    c = registry.get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        88, 12288, 96, 8, 28672, 32768)
    c = registry.get_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        42, 3584, 16, 8, 14336, 256000)
    c = registry.get_config("smollm-135m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        30, 576, 9, 3, 1536, 49152)
    c = registry.get_config("llama4-scout-17b-a16e")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        48, 5120, 40, 8, 8192, 202048) and (c.n_experts, c.top_k) == (16, 1)
    c = registry.get_config("whisper-tiny")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        4, 384, 6, 6, 1536, 51865)
    c = registry.get_config("llama-3.2-vision-11b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == (
        40, 4096, 32, 8, 14336, 128256)
    c = registry.get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (
        48, 1024, 50280, 128) and c.d_ff == 0
