"""Serving-engine tests: greedy spec decoding must exactly reproduce
autoregressive decoding (lossless at temperature 0 means token-identical),
continuous batching invariants, and verifier plumbing."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import Model
from repro.serving.engine import EngineConfig, SpecEngine


def _models(name, seed=0):
    cfg = registry.smoke_config(name)
    if cfg.n_experts:
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts) / cfg.top_k)
    tgt = Model(cfg)
    drf = Model(cfg.with_(d_model=128, d_ff=256 if cfg.d_ff else 0,
                          name=cfg.name + "-d"))
    kt, kd = jax.random.split(jax.random.key(seed))
    return tgt, drf, tgt.init(kt), drf.init(kd)


def _greedy_reference(model, params, prompt, n_new):
    seq = list(prompt)
    extras = model.make_extras(1)
    for _ in range(n_new):
        logits, _, _ = model.apply(
            params, jnp.asarray([seq], jnp.int32), extras=extras, mode="train"
        )
        seq.append(int(jnp.argmax(logits[0, -1, : model.cfg.vocab])))
    return seq[len(prompt):]


# A cross-section of families: dense-GQA, windowed MoE, SSM, hybrid.
FAMILIES = ["smollm-135m", "mixtral-8x22b", "mamba2-370m", "zamba2-1.2b"]


@pytest.mark.parametrize("name", FAMILIES)
@pytest.mark.parametrize("verifier", ["token", "block"])
def test_greedy_spec_equals_autoregressive(name, verifier):
    tgt, drf, tp, dp = _models(name)
    cfg = EngineConfig(
        gamma=4, verifier=verifier, max_slots=2, max_len=128,
        temperature=0.0, max_new_tokens=16,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    prompts = [[5, 3, 8, 1, 2], [9, 9, 2, 4, 4, 4, 7, 1, 0, 3, 2]]
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        ref = _greedy_reference(tgt, tp, p, 16)
        assert out[rid].output[:16] == ref, (name, verifier, rid)


def test_continuous_batching_more_requests_than_slots():
    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(
        gamma=3, verifier="block", max_slots=2, max_len=96,
        temperature=0.0, max_new_tokens=8,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    prompts = [[i + 1, i + 2, i + 3, 7] for i in range(5)]
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    for rid, p in zip(rids, prompts):
        assert out[rid].output[:8] == _greedy_reference(tgt, tp, p, 8), rid
        assert len(out[rid].output) == 8


def test_block_efficiency_at_least_one():
    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(
        gamma=4, verifier="block", max_slots=2, max_len=128,
        temperature=1.0, max_new_tokens=24,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    rids = [eng.submit([1, 2, 3, 4, 5]) for _ in range(2)]
    out = eng.run()
    for rid in rids:
        r = out[rid]
        assert r.iterations >= 1
        assert len(r.output) >= r.iterations  # >= 1 token per iteration
        be = len(r.output) / r.iterations
        assert 1.0 <= be <= cfg.gamma + 1


def test_sampled_spec_decoding_runs_all_verifiers():
    tgt, drf, tp, dp = _models("smollm-135m", seed=3)
    for verifier in ["token", "block", "greedy_block"]:
        cfg = EngineConfig(
            gamma=3, verifier=verifier, max_slots=1, max_len=96,
            temperature=0.8, max_new_tokens=12,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        rid = eng.submit([4, 2])
        out = eng.run()
        assert len(out[rid].output) == 12
        assert all(0 <= t < tgt.cfg.vocab for t in out[rid].output)


def test_chunked_prefill_long_prompt_matches_reference():
    """Prompts longer than prefill_chunk run through multiple chunked
    prefill steps; committed output must still be token-identical."""
    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(
        gamma=3, verifier="block", max_slots=2, max_len=128,
        temperature=0.0, max_new_tokens=8, prefill_chunk=8,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    prompts = [[(i * 7 + 3) % tgt.cfg.vocab for i in range(21)],
               [(i * 5 + 1) % tgt.cfg.vocab for i in range(4)]]
    rids = [eng.submit(p) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        assert out[rid].output[:8] == _greedy_reference(tgt, tp, p, 8), rid
    # 21-token prompt needs ceil(20/8) = 3 chunks; interleaved with the
    # short prompt's single chunk, all inside the same compiled program.
    assert eng.last_stats["prefill_steps"] >= 3


def test_token_and_block_commit_identical_greedy_sequences():
    """At temperature 0 with the same PRNG key, the token and block
    verifiers must commit identical sequences (both lossless)."""
    tgt, drf, tp, dp = _models("smollm-135m")
    outs = {}
    for verifier in ["token", "block"]:
        cfg = EngineConfig(
            gamma=4, verifier=verifier, max_slots=2, max_len=128,
            temperature=0.0, max_new_tokens=16,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        rids = [eng.submit(p) for p in ([3, 1, 4, 1, 5], [2, 7, 1, 8])]
        res = eng.run()
        outs[verifier] = [res[r].output for r in rids]
    assert outs["token"] == outs["block"]


def test_residual_backend_pallas_matches_jnp_in_engine():
    """The Pallas residual-sums kernel (forced via pallas_interpret on
    CPU) must produce the same committed sequences as the pure-jnp
    backend, at a sampled temperature with identical PRNG keys."""
    tgt, drf, tp, dp = _models("smollm-135m", seed=5)
    outs = {}
    for backend in ["jnp", "pallas_interpret"]:
        cfg = EngineConfig(
            gamma=4, verifier="block", max_slots=2, max_len=128,
            temperature=0.8, max_new_tokens=20, residual_backend=backend,
        )
        eng = SpecEngine(tgt, drf, tp, dp, cfg)
        rids = [eng.submit(p) for p in ([5, 3, 8], [1, 2, 3, 4])]
        res = eng.run()
        outs[backend] = [res[r].output for r in rids]
    assert outs["jnp"] == outs["pallas_interpret"]


def test_engine_routes_block_residuals_through_kernel_entry_point():
    from repro.core import verification
    from repro.kernels import ops

    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(gamma=2, verifier="block", max_slots=1, max_len=64)
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    assert cfg.residual_backend == "auto"
    assert (
        verification.resolve_residual_sums("auto")
        is ops.verify_residual_sums
    )
    # and the runner's bound verifier carries exactly that backend
    assert eng.runner.verify.keywords["residual_sums"] is (
        ops.verify_residual_sums
    )


def test_stats_count_max_len_guard_retirements():
    """Requests cut off by the max_len guard must still contribute their
    emitted tokens to throughput stats (regression: they were dropped)."""
    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(
        gamma=4, verifier="block", max_slots=2, max_len=48,
        temperature=0.0, max_new_tokens=500,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    rids = [eng.submit([1, 2, 3, 4, 5]) for _ in range(2)]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    total = sum(len(out[r].output) for r in rids)
    assert total > 0
    assert eng.last_stats["tokens"] == total
    for r in rids:
        assert out[r].finish_reason == "max_len_guard"


def test_request_metrics_reported():
    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(
        gamma=3, verifier="block", max_slots=2, max_len=96,
        temperature=0.0, max_new_tokens=8,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    rids = [eng.submit([4, 2, 7]) for _ in range(3)]  # 3 reqs, 2 slots
    eng.run()
    metrics = eng.request_metrics()
    assert sorted(m["rid"] for m in metrics) == sorted(rids)
    for m in metrics:
        assert m["output_len"] == 8
        assert m["ttft_s"] is not None and m["ttft_s"] >= 0
        assert m["tokens_per_s"] is not None and m["tokens_per_s"] > 0
        assert 0.0 <= m["acceptance_rate"] <= 1.0
        assert 1.0 <= m["block_efficiency"] <= cfg.gamma + 1
        assert m["finish_reason"] == "length"


def test_alloc_trace_decimation_keeps_first_and_last():
    """The old ``del trace[::2]`` dropped the EVEN indices — including
    sample 0 — so long runs lost the trace's start. The helper must keep
    both anchors and halve the middle."""
    from repro.serving.engine import _decimate_trace

    trace = [{"step": i} for i in range(1, 9)]          # odd last index
    kept = _decimate_trace(trace)
    assert [t["step"] for t in kept] == [1, 3, 5, 7, 8]
    trace = [{"step": i} for i in range(1, 10)]         # even last index
    kept = _decimate_trace(trace)
    assert [t["step"] for t in kept] == [1, 3, 5, 7, 9]
    assert _decimate_trace([{"step": 1}]) == [{"step": 1}]


def test_alloc_trace_capped_run_preserves_anchors(monkeypatch):
    """Drive a paged engine past the trace cap: the recorded series must
    stay bounded, keep its FIRST sample, end at the freshest recorded
    step, and report the doubled effective stride."""
    from repro.serving import engine as engine_mod

    monkeypatch.setattr(engine_mod, "ALLOC_TRACE_CAP", 8)
    tgt, drf, tp, dp = _models("smollm-135m")
    cfg = EngineConfig(
        gamma=2, verifier="block", max_slots=1, max_len=96,
        temperature=0.0, max_new_tokens=48,
    )
    eng = SpecEngine(tgt, drf, tp, dp, cfg)
    eng.submit([3, 1, 4, 1, 5])
    eng.run()
    stats = eng.last_stats
    trace = stats["alloc_trace"]
    iters = stats["iterations"]
    assert iters > 8  # the cap was actually hit
    assert len(trace) <= 8 + 1
    assert trace[0]["step"] == 1                  # first sample survives
    steps = [t["step"] for t in trace]
    assert steps == sorted(steps)
    stride = stats["alloc_trace_stride"]
    assert stride > 1 and (stride & (stride - 1)) == 0  # doubled, 2^k
    # the tail is never more than one stride stale
    assert iters - trace[-1]["step"] < stride
